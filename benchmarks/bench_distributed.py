"""Distributed-schedule benchmarks: Cannon/systolic phases on the ICI torus,
pipeline bubble fractions, and (in a 4-device subprocess) measured wall-time
of the overlapped ring collectives vs unfused all_gather+matmul.
"""

import os
import subprocess
import sys
import textwrap

from repro.parallel.pipeline import bubble_fraction
from repro.parallel.systolic import phase_counts


def run(csv=False):
    print("# distributed systolic matmul — collective phases (paper analogue)")
    print("p,chips,switched_phases,naive_phases,paper_mesh,paper_standard")
    for p in (2, 4, 8, 16, 32):
        pc = phase_counts(p)
        print(
            f"{p},{p*p},{pc['switched_phases']},{pc['naive_phases']},"
            f"{pc['paper_mesh_steps']},{pc['paper_standard_steps']}"
        )

    print("\n# GPipe bubble fraction (stages x microbatches)")
    print("stages,micro,bubble")
    for s in (2, 4, 8):
        for m in (4, 16, 64):
            print(f"{s},{m},{bubble_fraction(s, m):.4f}")

    print("\n# 4-device ring collective wall-time (subprocess, CPU devices)")
    prog = textwrap.dedent(
        """
        import time
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_local_mesh
        from repro.parallel.collectives import ring_allgather_matmul
        mesh = make_local_mesh((4,), ("model",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(1024, 512)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(512, 512)).astype(np.float32))
        ring = jax.jit(jax.shard_map(
            lambda xb, wb: ring_allgather_matmul(xb, wb, "model"),
            mesh=mesh, in_specs=(P("model", None), P()), out_specs=P(), check_vma=False))
        unfused = jax.jit(jax.shard_map(
            lambda xb, wb: jax.lax.all_gather(xb, "model", tiled=True) @ wb,
            mesh=mesh, in_specs=(P("model", None), P()), out_specs=P(), check_vma=False))
        for name, f in (("ring_overlapped", ring), ("allgather_then_matmul", unfused)):
            f(x, w).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(20):
                out = f(x, w)
            out.block_until_ready()
            print(f"{name},{(time.perf_counter()-t0)/20*1e3:.2f}ms")
        np.testing.assert_allclose(np.asarray(ring(x, w)), np.asarray(unfused(x, w)), rtol=1e-4, atol=1e-4)
        print("MATCH")
        """
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=560,
    )
    if out.returncode == 0:
        print(out.stdout.strip())
    else:  # don't fail the whole bench suite on subprocess quirks
        print(f"subprocess failed: {out.stderr[-500:]}")
    return True


if __name__ == "__main__":
    run()
