"""Benchmark harness entry point: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only <name>]

Sections:
  stepcounts   paper Figs 1-2 (2n-1 vs 3n-2) + ICI-torus phase analogue
  scramble     cycle structure/orders (7/7/20 + extension) + S^k throughput
  symmetric    symmetric-product early readout (<= n+1+n/2)
  kernels      mesh-matmul BlockSpec structure + allclose gate + GEMM context
  dispatch     plan/execute dispatch overhead (eager matmul vs pre-built Plan)
  moe          grouped-GEMM expert dispatch vs one-hot einsum (ms + bytes)
  sharded      ShardedPlan collective schedules: bytes-moved + step time
  costmodel    cost-model predicted vs measured ms + schedule-ranking accuracy
  obs          tracing overhead: disabled <2% contract + enabled spans/s
  distributed  Cannon phases, pipeline bubbles, ring-overlap wall-time
  serve        continuous-batching Poisson load: throughput + p50/p99 latency
  train        short real training run (loss trajectory) on the demo config
  roofline     renders the dry-run roofline table (artifacts/pod16x16)
"""

import argparse
import json
import platform
import time
import traceback

from benchmarks import (
    bench_costmodel,
    bench_dispatch,
    bench_distributed,
    bench_kernels,
    bench_moe,
    bench_obs,
    bench_roofline,
    bench_scramble,
    bench_serve,
    bench_sharded,
    bench_stepcounts,
    bench_symmetric,
)


def bench_train():
    """Short training run: the end-to-end sanity number for the harness."""
    from repro.configs import get_config
    from repro.launch.train import build_trainer

    cfg = get_config("mesh-paper").reduced()
    step_fn, state, data = build_trainer(cfg, batch=8, seq=64, lr=1e-3, total_steps=40)
    losses = []
    t0 = time.perf_counter()
    for _ in range(40):
        state, metrics = step_fn(state, next(data))
        losses.append(float(metrics["loss"]))
    dt = time.perf_counter() - t0
    print("# short training run (mesh-paper reduced, 40 steps)")
    print("steps,first_loss,last_loss,steps_per_s")
    print(f"40,{losses[0]:.4f},{losses[-1]:.4f},{40/dt:.2f}")
    assert losses[-1] < losses[0]
    return losses


SECTIONS = {
    "stepcounts": bench_stepcounts.run,
    "scramble": bench_scramble.run,
    "symmetric": bench_symmetric.run,
    "kernels": bench_kernels.run,
    "dispatch": bench_dispatch.run,
    "moe": bench_moe.run,
    "sharded": bench_sharded.run,
    "costmodel": bench_costmodel.run,
    "obs": bench_obs.run,
    "distributed": bench_distributed.run,
    "serve": bench_serve.run,
    "train": bench_train,
    "roofline": bench_roofline.run,
}


def _write_kernels_json(payload: dict, wall_s: float, out_path: str) -> None:
    """BENCH_kernels.json: the cross-PR perf-trajectory artifact (ISSUE 2).

    Structural metrics + host wall-times + the block shapes the autotuner
    chose, with enough provenance (jax version / backend) to compare runs."""
    import jax

    doc = {
        "version": 1,
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "host": platform.machine(),
        "wall_s": round(wall_s, 2),
        **payload,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    print(f"[kernels] wrote {out_path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(SECTIONS))
    ap.add_argument(
        "--json",
        action="store_true",
        help="write the kernels section's metrics to BENCH_kernels.json",
    )
    ap.add_argument("--json-path", default="BENCH_kernels.json")
    args = ap.parse_args()
    names = [args.only] if args.only else list(SECTIONS)
    if args.json and "kernels" not in names:
        names.append("kernels")
    if args.json and "kernels" in names:
        # the kernels --json branch already runs the dispatch/moe/sharded/
        # serve microbenches for its payload — don't time the same calls twice
        for ride_along in ("dispatch", "moe", "sharded", "costmodel", "obs", "serve"):
            if ride_along in names:
                names.remove(ride_along)
    failed = []
    for name in names:
        print(f"\n{'=' * 72}\n== bench: {name}\n{'=' * 72}")
        t0 = time.perf_counter()
        try:
            if name == "kernels" and args.json:
                payload = bench_kernels.run(as_dict=True)
                # dispatch-overhead + moe-dispatch + sharded-schedule
                # microbenches ride along in the same JSON so
                # BENCH_kernels.json tracks the plan-cache win, the grouped
                # vs one-hot dispatch cost, and per-schedule comm cost
                payload["dispatch"] = bench_dispatch.run(as_dict=True)
                payload["moe"] = bench_moe.run(as_dict=True)
                payload["sharded"] = bench_sharded.run(as_dict=True)
                payload["costmodel"] = bench_costmodel.run(as_dict=True)
                payload["obs"] = bench_obs.run(as_dict=True)
                payload["serve"] = bench_serve.run(as_dict=True)
                _write_kernels_json(payload, time.perf_counter() - t0, args.json_path)
            else:
                SECTIONS[name]()
            print(f"[{name}] done in {time.perf_counter() - t0:.1f}s")
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        raise SystemExit(f"benchmark sections failed: {failed}")
    print("\nALL BENCHES OK")


if __name__ == "__main__":
    main()
