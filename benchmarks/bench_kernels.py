"""Kernel-level benchmarks: mesh-matmul schedule analytics + GEMM wall-time.

CPU container caveat: Pallas runs in interpret mode here (Python per block —
not a performance measurement), so the kernel rows report the *structural*
quantities that determine TPU performance: VMEM working set per grid cell,
HBM bytes per block phase with/without the mesh stagger, and arithmetic
intensity.  Each row also records the block triple the autotuner resolves for
that shape (model-scored on CPU, timed on TPU — kernels/autotune.py).  XLA
GEMM wall-time is measured for scale context.

`run(as_dict=True)` returns the whole section as a JSON-able dict — the
payload `benchmarks/run.py --json` writes to BENCH_kernels.json so the perf
trajectory is tracked across PRs.
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.kernels import api, autotune
from repro.kernels.ref import matmul_ref


def kernel_structure_row(m, k, n, bm=128, bn=128, bk=128, dtype_bytes=2):
    gm, gn, gk = m // bm, n // bn, k // bk
    vmem_bytes = (bm * bk + bk * bn) * dtype_bytes + bm * bn * 4  # A + B tiles + f32 acc
    flops_per_phase = 2 * bm * bn * bk
    bytes_per_phase = (bm * bk + bk * bn) * dtype_bytes
    intensity = flops_per_phase / bytes_per_phase
    # stagger: the gm*gn concurrently-active cells request DISJOINT (A, B)
    # k-blocks each phase (Cannon alignment) -> unique-bytes = active cells x
    # per-cell; unstaggered: all cells hit the same k index -> gm + gn unique
    # row/col blocks per phase (broadcast-friendly but serializes HBM banks).
    unique_unstaggered = (gm * bm * bk + gn * bk * bn) * dtype_bytes
    unique_staggered = min(gm * gn, gk) * bytes_per_phase
    return dict(
        mkn=f"{m}x{k}x{n}",
        grid=f"{gm}x{gn}x{gk}",
        vmem_per_cell_kb=vmem_bytes // 1024,
        flops_per_phase=flops_per_phase,
        intensity_flops_per_byte=round(intensity, 1),
        unique_bytes_phase_std=unique_unstaggered,
        unique_bytes_phase_mesh=unique_staggered,
    )


BENCH_SHAPES = [
    (512, 512, 512),
    (4096, 4096, 4096),
    (8192, 1024, 8192),
    (2048, 16384, 2048),
]


def run(csv=False, as_dict=False):
    result = {"structure": [], "autotune": {}, "xla_gemm": [], "allclose_max_err": None}

    print("# mesh-matmul kernel structure (TPU-facing; autotuned block shapes)")
    for m, k, n in BENCH_SHAPES:
        bm, bn, bk = autotune.autotune(m, k, n, jnp.bfloat16, "pallas_mesh")
        row = kernel_structure_row(m, k, n, bm=bm, bn=bn, bk=bk)
        row["blocks"] = f"{bm}x{bn}x{bk}"
        result["structure"].append(row)
        result["autotune"][f"{m}x{k}x{n}|bfloat16"] = [bm, bn, bk]
    header = list(result["structure"][0])
    print(",".join(header))
    for r in result["structure"]:
        print(",".join(str(r[key]) for key in header))

    print("\n# XLA GEMM wall-time on this host (scale context only; plan/execute)")
    print("mkn,dtype,ms,gflops")
    rng = np.random.default_rng(0)
    for m, k, n in ((512, 512, 512), (1024, 1024, 1024), (2048, 2048, 2048)):
        a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        # planned once per shape; the loop times the RAW jitted executor so
        # the series stays comparable with pre-plan-API numbers (per-call
        # validation overhead is measured separately by the dispatch bench)
        f = api.plan(api.GemmSpec.from_operands(a, b), backend="xla").executor
        f(a, b, None, None).block_until_ready()
        t0 = time.perf_counter()
        iters = 10
        for _ in range(iters):
            out = f(a, b, None, None)
        out.block_until_ready()
        dt = (time.perf_counter() - t0) / iters
        print(f"{m}x{k}x{n},f32,{dt*1e3:.2f},{2*m*k*n/dt/1e9:.1f}")
        result["xla_gemm"].append(
            dict(mkn=f"{m}x{k}x{n}", dtype="f32", ms=round(dt * 1e3, 3),
                 gflops=round(2 * m * k * n / dt / 1e9, 1))
        )

    print("\n# Pallas kernel allclose sweep (interpret mode) — correctness gate")
    from repro.kernels.mesh_matmul import mesh_matmul_pallas

    B = 16
    worst = 0.0
    for gm, gk, gn in ((1, 1, 1), (2, 3, 2), (4, 2, 3)):
        a = jnp.asarray(rng.normal(size=(gm * B, gk * B)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(gk * B, gn * B)).astype(np.float32))
        for stagger in (True, False):
            got = mesh_matmul_pallas(
                a, b, block_m=B, block_n=B, block_k=B, stagger=stagger, interpret=True
            )
            err = float(jnp.max(jnp.abs(got - matmul_ref(a, b))))
            worst = max(worst, err)
    # fused-epilogue gate rides along: one bias+activation cell
    bias = jnp.asarray(rng.normal(size=(2 * B,)).astype(np.float32))
    a = jnp.asarray(rng.normal(size=(2 * B, B)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(B, 2 * B)).astype(np.float32))
    got = mesh_matmul_pallas(
        a, b, bias=bias, activation="relu", block_m=B, block_n=B, block_k=B,
        interpret=True,
    )
    err = float(jnp.max(jnp.abs(got - jnp.maximum(a @ b + bias, 0.0))))
    worst = max(worst, err)
    print(f"max_abs_err,{worst:.2e}")
    assert worst < 1e-4
    result["allclose_max_err"] = worst
    return result if as_dict else result["structure"]


if __name__ == "__main__":
    run()
