"""Continuous-batching serving under load: throughput + tail latency.

Drives `launch/scheduler.ContinuousBatchingServer` with a deterministic
Poisson arrival trace that OVERSUBSCRIBES the server (more concurrent work
than slots + pages can hold), so the numbers exercise the whole ladder:
admission queueing, page growth, preemption, and shedding — not just the
steady-state decode loop.  Two runs over the same trace:

  healthy   no faults armed — the baseline throughput / latency row
  chaos     the `ci-default` fault plan armed (serve.admit, serve.step,
            kv.page_alloc + the PR-6 sites) — the run must complete with
            the injected faults absorbed as sheds/skips/stalls, and the
            row quantifies what one fault per site costs

Latency is per-request wall time from submit to retirement (p50/p99 over
served requests); throughput is decode tokens per second of drive time.
`run(as_dict=True)` returns the JSON payload merged into
BENCH_kernels.json["serve"] by `benchmarks/run.py --json`.
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.scheduler import ContinuousBatchingServer, Request, ServeConfig
from repro.models import get_model
from repro.resilience import faults, ledger

ARCH = "mesh-paper"
N_REQUESTS = 24
PROMPT_LEN = 8
MAX_NEW = 12
ARRIVAL_RATE = 1.5  # mean requests per tick (Poisson) — oversubscribes 4 slots


def _poisson_trace(rng):
    """Deterministic oversubscribed trace: Poisson arrivals, mixed sizes."""
    arrivals = np.cumsum(rng.poisson(1.0 / ARRIVAL_RATE, size=N_REQUESTS))
    reqs = []
    for i in range(N_REQUESTS):
        prompt = rng.integers(0, 256, size=PROMPT_LEN).astype(np.int32)
        reqs.append(
            Request(
                rid=f"r{i:02d}",
                prompt=prompt,
                max_new_tokens=int(MAX_NEW - (i % 3)),  # mixed lengths
                priority=int(i % 2),
                arrival=int(arrivals[i]),
            )
        )
    return reqs


def _drive(model, params, requests):
    scfg = ServeConfig(
        max_slots=4,
        page_size=8,
        num_pages=13,  # 12 usable: 3 pages/seq -> 4 full seqs, growth contended
        max_pages_per_seq=3,
        queue_capacity=8,  # < N_REQUESTS: overflow sheds
        default_deadline=256,
        warmup_prompt_lens=(PROMPT_LEN,),
    )
    server = ContinuousBatchingServer(model, params, scfg)
    server.warmup()
    t0 = time.perf_counter()
    results = server.run(requests)
    wall = time.perf_counter() - t0
    lat = sorted(r.latency_s for r in results.values() if r.status == "ok")
    row = {
        "wall_s": round(wall, 3),
        "ticks": server.counters["ticks"],
        "decode_tokens": server.counters["decode_tokens"],
        "tok_per_s": round(server.counters["decode_tokens"] / wall, 1),
        "served": server.counters["served"],
        "shed": server.counters["shed"],
        "timeout": server.counters["timeout"],
        "preempted": server.counters["preempted"],
        "skipped_ticks": server.counters["skipped_ticks"],
        "p50_latency_ms": round(1e3 * lat[len(lat) // 2], 1) if lat else None,
        "p99_latency_ms": round(
            1e3 * lat[min(len(lat) - 1, int(len(lat) * 0.99))], 1
        ) if lat else None,
    }
    return row


def run(as_dict=False):
    cfg = get_config(ARCH).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    requests = _poisson_trace(np.random.default_rng(7))

    rows = {"healthy": _drive(model, params, requests)}

    # Same trace with every ci-default fault armed (one trigger per site):
    # the acceptance bar is completion + graceful absorption, the row is
    # the cost. env REPRO_FAULT_PLAN=ci-default reaches the same plan via
    # the CI chaos job; arming it in-process keeps this bench hermetic.
    ledger.clear()
    with faults.inject(dict(faults.CANNED_PLANS["ci-default"])):
        rows["chaos_ci_default"] = _drive(model, params, requests)
    rows["chaos_ci_default"]["ledger_events"] = ledger.count()
    assert rows["chaos_ci_default"]["skipped_ticks"] >= 1
    assert rows["chaos_ci_default"]["served"] >= 1
    ledger.clear()

    print(f"# continuous-batching serve: {N_REQUESTS} Poisson requests, "
          f"rate {ARRIVAL_RATE}/tick, 4 slots, 12 usable pages ({ARCH} reduced)")
    cols = ["tok_per_s", "p50_latency_ms", "p99_latency_ms", "served", "shed",
            "timeout", "preempted", "skipped_ticks"]
    print("run," + ",".join(cols))
    for name, row in rows.items():
        print(name + "," + ",".join(str(row[c]) for c in cols))

    result = {
        "arch": ARCH,
        "requests": N_REQUESTS,
        "prompt_len": PROMPT_LEN,
        "arrival_rate_per_tick": ARRIVAL_RATE,
        **rows,
    }
    return result if as_dict else rows


if __name__ == "__main__":
    run()
