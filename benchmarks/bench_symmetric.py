"""Paper Discussion: symmetric-product early readout in ~3n/2 steps.

One row per n: readout horizon under the anti-diagonal schedule, the paper's
bound n+1+n/2, the general horizon 2n-1, the standard array's 3n-2, and the
fraction of entries already readable at the symmetric horizon.
"""

from repro.core.mesh_array import mesh_completion_times
from repro.core.symmetries import (
    paper_symmetric_bound,
    symmetric_readout_schedule,
    symmetric_readout_steps,
)


def run(csv=False):
    print("# symmetric-product early readout (paper: <= n+1+n/2 steps)")
    print("n,symmetric_steps,paper_bound,mesh_steps,standard_steps,saving_vs_mesh,saving_vs_standard")
    for n in (2, 3, 4, 6, 8, 12, 16, 24, 32, 64):
        s = symmetric_readout_steps(n)
        bound = paper_symmetric_bound(n)
        mesh = 2 * n - 1
        std = 3 * n - 2
        assert s <= bound <= std
        print(
            f"{n},{s},{bound},{mesh},{std},{(mesh - s) / mesh:.3f},{(std - s) / std:.3f}"
        )

    print("\n# per-entry completion profile, n=8 (step at which each c_pq is readable)")
    n = 8
    sched = symmetric_readout_schedule(n)
    times = mesh_completion_times(n)
    by_step = {}
    for (p, q), (_, t) in sched.items():
        by_step[t] = by_step.get(t, 0) + 1
    print("step,entries_ready(symmetric),entries_ready(general)")
    gen = {}
    for i in range(n):
        for j in range(n):
            t = int(times[i, j])
            gen[t] = gen.get(t, 0) + 1
    cum_s = cum_g = 0
    for t in range(1, 2 * n):
        cum_s += by_step.get(t, 0)
        cum_g += gen.get(t, 0)
        print(f"{t},{cum_s},{cum_g}")
    return True


if __name__ == "__main__":
    run()
