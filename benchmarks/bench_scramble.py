"""Paper's scrambling-transformation section: cycle structure, orders, and
S^k application throughput.

Tables:
  1. order(S) for n = 2..24 with cycle-length multiset (extends the paper's
     7 / 7 / 20 values for n = 3, 4, 5),
  2. S^k application bandwidth at element and block granularity (the gather
     is one fused op regardless of k — the 'O(1) metadata' claim).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scramble import (
    apply_scramble,
    cycle_decomposition,
    scramble_order,
)
from repro.kernels.ops import scramble_blocks


def run(csv=False):
    print("# scrambling transformation S — cycle structure (paper: 7, 7, 20)")
    print("n,order,cycle_lengths")
    orders = {}
    for n in range(2, 25):
        lens = sorted((len(c) for c in cycle_decomposition(n)), reverse=True)
        orders[n] = scramble_order(n)
        print(f"{n},{orders[n]},{'+'.join(map(str, lens))}")
    assert orders[3] == 7 and orders[4] == 7 and orders[5] == 20

    print("\n# S^k application throughput (single fused gather for any k)")
    print("n,k,bytes,us_per_call,GB_s")
    rng = np.random.default_rng(0)
    for n in (64, 256, 1024):
        x = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
        for k in (1, 1000, -3):
            f = jax.jit(lambda t, k=k: apply_scramble(t, k))
            f(x).block_until_ready()
            t0 = time.perf_counter()
            iters = 50
            for _ in range(iters):
                out = f(x)
            out.block_until_ready()
            dt = (time.perf_counter() - t0) / iters
            nbytes = x.size * 4 * 2  # read + write
            print(f"{n},{k},{nbytes},{dt*1e6:.1f},{nbytes/dt/1e9:.2f}")

    print("\n# block-granularity S (Pallas schedule, interpret on CPU)")
    print("grid,block,us_per_call")
    for g, blk in ((4, 32), (8, 32)):
        x = jnp.asarray(rng.normal(size=(g * blk, g * blk)).astype(np.float32))
        scramble_blocks(x, block_m=blk, block_n=blk, k=1).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            out = scramble_blocks(x, block_m=blk, block_n=blk, k=1)
        out.block_until_ready()
        print(f"{g}x{g},{blk},{(time.perf_counter()-t0)/5*1e6:.1f}")
    return orders


if __name__ == "__main__":
    run()
