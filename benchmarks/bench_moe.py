"""MoE expert-dispatch benchmark (ISSUE 5): grouped plan vs one-hot einsum.

The grouped-GEMM planner replaced the Switch-style dense dispatch — a
(groups, s, e, cap) one-hot tensor driving dispatch/combine einsums — with a
sort/segment permutation feeding ONE ragged kernel per expert projection
(models/moe.py, DESIGN.md §10).  This section times both expert paths on the
same routing decisions at a reduced shape and reports, per layer:

  grouped_ms       sort + scatter + two grouped plans + gather/combine
  onehot_ms        one-hot dispatch einsum + two dense einsums + combine
  dispatch bytes   routing traffic each path streams: the one-hot path
                   materializes the (n, e, cap) dispatch/combine tensors;
                   the grouped path scatters rows in and gathers them out
                   (the GroupedPlan's own dispatch_bytes provenance)

`run(as_dict=True)` rides into BENCH_kernels.json under "moe" via
`benchmarks/run.py --json`, tracking the dispatch win across PRs.  CPU
numbers are structural (XLA backend either way); the kernel-level win is a
TPU measurement.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import api
from repro.models.layers import NO_SHARD, init_params
from repro.models.moe import moe_block, moe_specs

BATCH = 4
SEQ = 256  # <= _EXACT_GROUP: exact drop-free routing on both paths
N_TOKENS = BATCH * SEQ
D_MODEL = 64
N_EXPERTS = 8
TOP_K = 2
D_FF = 128
STEPS = 20


class _Cfg:
    """Just enough config surface for moe_specs/moe_block."""

    d_model = D_MODEL
    num_experts = N_EXPERTS
    num_experts_per_tok = TOP_K
    moe_d_ff = D_FF
    num_layers = 2
    num_shared_experts = 0
    use_mesh_kernel = False
    mesh_block_m = mesh_block_n = mesh_block_k = 0
    param_dtype = "float32"
    fused_dense_epilogue = True

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)


def onehot_moe_reference(p, x, cfg, ctx=None, capacity_factor=1.25):
    """The pre-refactor dense one-hot dispatch (PR 4 models/moe.py),
    preserved verbatim as the SINGLE in-tree oracle: the benchmark baseline
    here and the drop-free equivalence oracle in tests/test_grouped.py.
    Returns (y, aux) exactly like moe_block; `ctx` is ignored (the old
    sharding constraints don't change CPU numerics)."""
    del ctx
    b, t, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    n = b * t
    s = min(1024, t) if t > 1 else min(1024, n)
    while n % s:
        s //= 2
    g = n // s
    cap = s if s <= 256 else max(1, int(capacity_factor * s * k / e))

    xg = x.reshape(g, s, d)
    logits = jnp.einsum(
        "gsd,de->gse", xg.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.float32)
    flat = onehot.reshape(g, s * k, e)
    pos = (jnp.cumsum(flat, axis=1) - 1.0).reshape(g, s, k, e)
    pos = jnp.sum(pos * onehot, axis=-1)
    keep = pos < cap
    gate = topv * keep.astype(topv.dtype)
    cap_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=xg.dtype)
    onehot_keep = onehot.astype(xg.dtype) * keep[..., None].astype(xg.dtype)
    disp = jnp.einsum("gske,gskc->gsec", onehot_keep, cap_oh)
    ex_in = jnp.einsum("gsec,gsd->gecd", disp, xg)
    gate_up = jnp.einsum("gecd,edf->gecf", ex_in, p["wi"])
    gate_h, up_h = jnp.split(gate_up, 2, axis=-1)
    h = jax.nn.silu(gate_h) * up_h
    ex_out = jnp.einsum("gecf,efd->gecd", h, p["wo"])
    combine = jnp.einsum(
        "gske,gskc->gsec", onehot_keep * gate.astype(xg.dtype)[..., None], cap_oh
    )
    y = jnp.einsum("gsec,gecd->gsd", combine, ex_out).reshape(b, t, d)

    if cfg.num_shared_experts:
        xf = x.reshape(n, d)
        sg = jax.nn.sigmoid(
            jnp.einsum(
                "nd,do->no",
                xf.astype(jnp.float32),
                p["shared_gate"].astype(jnp.float32),
            )
        ).astype(x.dtype)
        gu = jnp.einsum("nd,df->nf", xf, p["shared_wi"])
        g_, u_ = jnp.split(gu, 2, axis=-1)
        shared = jnp.einsum("nf,fd->nd", jax.nn.silu(g_) * u_, p["shared_wo"])
        y = y + (shared * sg).reshape(b, t, d)

    load = jnp.mean(onehot.sum(2), axis=(0, 1))
    imp = jnp.mean(probs, axis=(0, 1))
    lb_loss = e * jnp.sum(load * imp) / k
    router_z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return y, {"lb_loss": lb_loss, "router_z": router_z}


def _time_ms(fn, *args):
    fn(*args).block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(STEPS):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / STEPS * 1e3


def run(as_dict: bool = False):
    cfg = _Cfg()
    rng = np.random.default_rng(0)
    params = init_params(jax.random.PRNGKey(0), moe_specs(cfg), cfg.pdtype)
    x = jnp.asarray(
        rng.normal(size=(BATCH, SEQ, D_MODEL)).astype(np.float32)
    )
    cap = N_TOKENS  # drop-free at this shape (both paths route exactly)

    grouped = jax.jit(lambda pp, xx: moe_block(pp, xx, cfg, NO_SHARD)[0])
    onehot = jax.jit(lambda pp, xx: onehot_moe_reference(pp, xx, cfg)[0])

    y_g = grouped(params, x)
    y_o = onehot(params, x)
    np.testing.assert_allclose(
        np.asarray(y_g), np.asarray(y_o), rtol=1e-5, atol=1e-5
    )  # same routing semantics before any timing claims

    grouped_ms = _time_ms(grouped, params, x)
    onehot_ms = _time_ms(onehot, params, x)

    # Dispatch-traffic provenance: grouped from the plan's own record;
    # one-hot from the (groups, s, e, cap) dispatch+combine tensors the
    # baseline actually materializes (cap derived exactly as the reference
    # does — per notional group, not globally).
    grouped_plans = [
        p
        for p in api.plan_cache_info()["plans"]
        if p.get("grouped")
        and p["grouped"]["num_groups"] == N_EXPERTS
        and p["grouped"]["rows_per_group"] >= cap
    ]
    disp_grouped = sum(p["grouped"]["dispatch_bytes"] for p in grouped_plans)
    itemsize = np.dtype(np.float32).itemsize
    cap_pg = SEQ if SEQ <= 256 else max(1, int(1.25 * SEQ * TOP_K / N_EXPERTS))
    disp_onehot = 2 * N_TOKENS * N_EXPERTS * cap_pg * itemsize
    # ...and the FLOPs those tensors cost: dispatch + combine einsums contract
    # over d per (token, expert, slot); the sort/scatter path moves rows
    # without multiplying anything.
    disp_flops_onehot = 4 * N_TOKENS * N_EXPERTS * cap_pg * D_MODEL

    payload = {
        "shape": {
            "tokens": N_TOKENS,
            "d_model": D_MODEL,
            "experts": N_EXPERTS,
            "top_k": TOP_K,
            "d_ff": D_FF,
            "capacity": cap,
        },
        "grouped_ms_per_layer": round(grouped_ms, 3),
        "onehot_ms_per_layer": round(onehot_ms, 3),
        "dispatch_bytes_grouped": disp_grouped,
        "dispatch_bytes_onehot": disp_onehot,
        "dispatch_flops_onehot": disp_flops_onehot,
        "dispatch_flops_grouped": 0,  # sort/scatter/gather: no MACs
        "grouped_plans": len(grouped_plans),
    }
    print("# MoE expert dispatch: grouped plan vs one-hot einsum (drop-free)")
    print("path,ms_per_layer,dispatch_bytes,dispatch_flops")
    print(f"grouped,{grouped_ms:.3f},{disp_grouped},0")
    print(f"onehot,{onehot_ms:.3f},{disp_onehot},{disp_flops_onehot}")
    print(
        f"routing overhead removed: {disp_flops_onehot:.2e} dispatch-einsum"
        f" FLOPs/layer; ms ratio {onehot_ms / max(grouped_ms, 1e-9):.1f}x"
    )
    if as_dict:
        return payload


if __name__ == "__main__":
    run()
